#!/usr/bin/env python
"""Dump / validate a plan-aware checkpoint manifest (train/checkpoint.py).

    python tools/inspect_ckpt.py <ckpt_dir> [--step N] [--json]

Human mode prints the step, per-leaf layout table (global shape, dtype,
sharded dims, shard count/bytes) and the recorded plan + topology; ``--json``
emits one machine-readable object (the CI smoke checks its schema).  Exits
non-zero with a message when the manifest or its shard files are corrupt —
so a broken checkpoint is diagnosable straight from CI logs.

Deliberately imports neither jax nor repro: inspection must work on a login
node (or in a failing CI job) without bringing up a device runtime.
"""
import argparse
import json
import os
import re
import sys

import numpy as np


def _np_dtype(name):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def all_steps(directory):
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def inspect(directory, step=None):
    """Validated summary dict for one checkpoint step (raises on
    corruption: missing/oversized shard files, incomplete coverage)."""
    steps = all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    step = step if step is not None else steps[-1]
    if step not in steps:
        raise FileNotFoundError(f"step {step} not in {steps}")
    base = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(base, "manifest.json")) as f:
        man = json.load(f)

    leaves, total_bytes = [], 0
    for rec in man.get("leaves", []):
        shape = tuple(int(d) for d in rec["shape"])
        dtype = _np_dtype(rec["dtype"])
        total = 1
        for d in shape:
            total *= d
        covered, nbytes = 0, 0
        for sh in rec["shards"]:
            path = os.path.join(base, sh["file"])
            if not os.path.exists(path):
                raise ValueError(f"leaf {rec['key']!r}: shard file "
                                 f"{sh['file']} is missing")
            n = 1
            for s, e in sh["index"]:
                n *= e - s
            want = n * dtype.itemsize
            have = os.path.getsize(path)
            if have < want:     # npy header adds bytes; less data cannot
                raise ValueError(
                    f"leaf {rec['key']!r}: shard {sh['file']} holds "
                    f"{have}B < {want}B of data")
            covered += n
            nbytes += want
        if covered != total:
            raise ValueError(f"leaf {rec['key']!r}: shards cover {covered} "
                             f"of {total} elements")
        total_bytes += nbytes
        leaves.append({"key": rec["key"], "shape": list(shape),
                       "dtype": rec["dtype"],
                       "sharded_dims": rec["sharded_dims"],
                       "n_shards": len(rec["shards"]), "bytes": nbytes})

    return {"dir": directory, "step": step, "steps": steps,
            "format": man.get("format"), "n_leaves": len(leaves),
            "total_bytes": total_bytes, "leaves": leaves,
            "plan": man.get("plan"), "topology": man.get("topology"),
            "meta": man.get("meta")}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("dir")
    ap.add_argument("--step", type=int, default=None)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    try:
        info = inspect(args.dir, args.step)
    except (OSError, ValueError, KeyError) as e:
        print(f"inspect_ckpt: {e}", file=sys.stderr)
        return 1
    if args.json:
        print(json.dumps(info))
        return 0
    print(f"{info['dir']}: step {info['step']} of {info['steps']} "
          f"({info['format']}, {info['n_leaves']} leaves, "
          f"{info['total_bytes'] / 1e6:.2f} MB)")
    for l in info["leaves"]:
        dims = ",".join(str(d) for d in l["sharded_dims"]) or "-"
        print(f"  {l['key']:<40} {str(tuple(l['shape'])):<20} "
              f"{l['dtype']:<10} sharded[{dims}] x{l['n_shards']}")
    if info["plan"] is not None:
        print(f"  plan: {info['plan']}")
    if info["topology"] is not None:
        axes = ", ".join(f"{a['name']}x{a['size']}"
                         for a in info["topology"]["axes"])
        print(f"  topology: {axes}")
    if info["meta"]:
        print(f"  meta: {info['meta']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
