"""End-to-end training driver example: trains a ~100M-param 2D transformer
(the paper's model family) for a few hundred steps with the full stack —
AdamW, remat, checkpointing, resume — and verifies the loss falls.

This is the paper's workload (video DiT diffusion training) at laptop scale.
Run:  PYTHONPATH=src python examples/train_video_dsp.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, make_batch
from repro.models.transformer2d import T2DConfig, init_t2d, t2d_loss
from repro.optim.adamw import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    # ~100M params: 8 blocks at d=1024 (12 * 1024^2 * 8 ~= 100M + modulation)
    cfg = T2DConfig(name="t2d-100m", n_layers=8, d_model=1024, n_heads=16,
                    d_ff=2048, in_dim=16, modulate=False, dtype=jnp.float32)
    from repro.models.transformer2d import t2d_param_count
    print(f"params: {t2d_param_count(cfg)/1e6:.0f}M")
    params = init_t2d(jax.random.PRNGKey(0), cfg)

    # learnable synthetic task: predict x itself slightly transformed
    dcfg = DataConfig(task="video", batch=2, temporal=4, spatial=32,
                      in_dim=cfg.in_dim)

    def data_fn(step):
        b = make_batch(dcfg, step)
        # target = rolled input => learnable mapping (not pure noise)
        b["target"] = jnp.roll(b["x"], 1, axis=-1)
        return b

    def loss_fn(p, b):
        return t2d_loss(p, b, cfg, backend="ref")

    with tempfile.TemporaryDirectory() as ckpt:
        tr = Trainer(loss_fn=loss_fn, params=params,
                     opt_cfg=OptConfig(peak_lr=1e-3,
                                       warmup_steps=args.steps // 10,
                                       total_steps=args.steps),
                     cfg=TrainerConfig(total_steps=args.steps,
                                       log_every=max(args.steps // 10, 1),
                                       ckpt_every=args.steps // 2),
                     data_fn=data_fn, ckpt_dir=ckpt)
        out = tr.run()
    hist = out["history"]
    print("loss:", " -> ".join(f"{l:.4f}" for _, l in hist))
    assert hist[-1][1] < hist[0][1], "loss must decrease"
    print("OK")


if __name__ == "__main__":
    main()
