"""Fault-tolerance example: train, "lose" the job, restart ELASTICALLY on a
different device count, and continue bit-exact.

Phase 1 trains on 1 device and checkpoints.  Phase 2 (a subprocess with 8
simulated devices) restores the same checkpoint onto a (4, 2) mesh with
ZeRO-sharded parameters and keeps training.  The data pipeline is a pure
function of the step, so the resumed loss curve continues seamlessly.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(HERE, "..", "src")

PHASE2 = r"""
import json, sys
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.data.pipeline import DataConfig, make_batch
from repro.models.lm import LMConfig, init_lm, lm_loss
from repro.optim.adamw import OptConfig
from repro.parallel.partition import ParallelPlan, param_pspecs, make_sharder
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import Trainer, TrainerConfig

ckpt_dir = sys.argv[1]
cfg = LMConfig(name="elastic", n_layers=2, d_model=64, n_heads=4,
               n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
               dtype=jnp.float32)
from repro.core.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
plan = ParallelPlan(mode="dsp")
sharder = make_sharder(mesh, plan)
params = init_lm(jax.random.PRNGKey(0), cfg)
specs = param_pspecs(params, plan, axis_sizes=dict(mesh.shape))
template = jax.tree_util.tree_map(
    lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                      sharding=NamedSharding(mesh, s)),
    params, specs)

dcfg = DataConfig(task="lm_shift", vocab=64, seq=64, batch=8)
tr = Trainer(loss_fn=lambda p, b: lm_loss(p, b, cfg, sharder=sharder,
                                          backend="ref"),
             params=params,
             opt_cfg=OptConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60),
             cfg=TrainerConfig(total_steps=60, log_every=10, ckpt_every=0),
             data_fn=lambda s: make_batch(dcfg, s), ckpt_dir=ckpt_dir)
mgr = CheckpointManager(ckpt_dir)
step, tree = mgr.restore({"params": template})
tr.params = tree["params"]
tr.start_step = step
print(f"resumed at step {step} on {len(jax.devices())} devices; "
      f"params sharded over mesh {dict(mesh.shape)}")
out = tr.run()
print(json.dumps(out["history"]))
"""


def main():
    with tempfile.TemporaryDirectory() as ckpt:
        # phase 1: single device
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        from repro.data.pipeline import DataConfig, make_batch
        from repro.models.lm import LMConfig, init_lm, lm_loss
        from repro.optim.adamw import OptConfig
        from repro.train.trainer import Trainer, TrainerConfig
        import jax, jax.numpy as jnp

        cfg = LMConfig(name="elastic", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, head_dim=16, d_ff=128, vocab=64,
                       dtype=jnp.float32)
        dcfg = DataConfig(task="lm_shift", vocab=64, seq=64, batch=8)
        tr = Trainer(loss_fn=lambda p, b: lm_loss(p, b, cfg, backend="ref"),
                     params=init_lm(jax.random.PRNGKey(0), cfg),
                     opt_cfg=OptConfig(peak_lr=3e-3, warmup_steps=5,
                                       total_steps=60),
                     cfg=TrainerConfig(total_steps=30, log_every=10,
                                       ckpt_every=30),
                     data_fn=lambda s: make_batch(dcfg, s), ckpt_dir=ckpt)
        out1 = tr.run()
        print("phase1 (1 device):", out1["history"])

        # phase 2: resume on 8 simulated devices with sharded params
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        proc = subprocess.run([sys.executable, "-c", PHASE2, ckpt],
                              env=env, capture_output=True, text=True,
                              timeout=600)
        print(proc.stdout)
        assert proc.returncode == 0, proc.stderr[-2000:]
        hist2 = json.loads(proc.stdout.strip().splitlines()[-1])
        assert hist2[-1][1] < out1["history"][0][1], "loss keeps improving"
        print("OK — elastic restart onto 8 devices continued training")


if __name__ == "__main__":
    main()
