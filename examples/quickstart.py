"""Quickstart: DSP in 40 lines.

Builds the paper's 2D (spatial-temporal) transformer, runs it under Dynamic
Sequence Parallelism on a simulated 8-device mesh, and shows the headline
property: the compiled program contains exactly TWO all-to-alls per layer
pair (Table 2) and matches the single-device reference bit-for-bit-ish.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.analysis.roofline import parse_collectives
from repro.models.transformer2d import (T2DConfig, init_t2d, forward,
                                        make_spmd_forward)

# a small video DiT: 4 blocks (2 spatial + 2 temporal), d=128
cfg = T2DConfig(name="quickstart", n_layers=4, d_model=128, n_heads=8,
                d_ff=256, in_dim=16, dtype=jnp.float32)
params = init_t2d(jax.random.PRNGKey(0), cfg)

# latent video: batch 2, 16 frames, 32 spatial tokens
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32, cfg.in_dim))
t = jax.random.uniform(jax.random.PRNGKey(2), (2,))

# single-device reference
ref = forward(params, x, t, cfg, backend="ref", remat=False)

# DSP on a (data=2, model=4) mesh: sequence sharded on T, dynamically
# switched to S for the temporal stage — one all-to-all per switch
from repro.core.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
dsp_fwd = jax.jit(make_spmd_forward(cfg, mesh, mode="dsp", backend="ref"))
out = dsp_fwd(params, x, t)

err = float(jnp.abs(out - ref).max()) / float(jnp.abs(ref).max())
print(f"DSP vs single-device relative error: {err:.2e}")

stats = parse_collectives(dsp_fwd.lower(params, x, t).compile().as_text())
pairs = cfg.n_layers // 2
print(f"collectives: {stats.by_kind_count}  "
      f"(expect all-to-all == 2 x {pairs} layer pairs)")
assert stats.by_kind_count.get("all-to-all") == 2 * pairs
assert err < 1e-4
print("OK — dynamic switch == 2 all-to-alls per layer pair, exact output")
