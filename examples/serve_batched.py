"""Serving example: train a tiny LM on the shift task until it is
near-perfect, then serve batched requests through the engine (prefill +
KV-cache decode) and check the generations actually follow the learned rule
— first through the static reference path, then through the
continuous-batching scheduler with staggered arrivals (slot reuse,
streaming, per-request TTFT).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, make_batch
from repro.models.lm import LMConfig, init_lm, lm_loss
from repro.optim.adamw import OptConfig
from repro.serving.engine import Request, ServingEngine
from repro.train.trainer import Trainer, TrainerConfig

cfg = LMConfig(name="shift-lm", n_layers=2, d_model=128, n_heads=4,
               n_kv_heads=2, head_dim=32, d_ff=256, vocab=64,
               dtype=jnp.float32)
params = init_lm(jax.random.PRNGKey(0), cfg)

dcfg = DataConfig(task="lm_shift", vocab=64, seq=64, batch=16, noise=0.0)
tr = Trainer(loss_fn=lambda p, b: lm_loss(p, b, cfg, backend="ref"),
             params=params,
             opt_cfg=OptConfig(peak_lr=3e-3, warmup_steps=20,
                               total_steps=300),
             cfg=TrainerConfig(total_steps=300, log_every=50, ckpt_every=0),
             data_fn=lambda s: make_batch(dcfg, s))
out = tr.run()
print("training loss:", " -> ".join(f"{l:.3f}" for _, l in out["history"]))

engine = ServingEngine(tr.params, cfg, max_len=64)
prompts = jax.random.randint(jax.random.PRNGKey(9), (4, 12), 0, 64)
gen = np.asarray(engine.generate(prompts, max_new_tokens=8))
want = (np.asarray(prompts)[:, -1:] + 1 + np.arange(8)) % 64
acc = float((gen == want).mean())
print("generations:", gen.tolist())
print(f"shift-rule accuracy: {acc:.2%}")
assert acc > 0.9, "the served model should follow the learned +1 rule"

# per-request decode budgets: the same batch, each request stopping at its
# own max_new_tokens (masked rows keep stepping through the one jitted
# decode — no retraces, no ragged batch)
reqs = [Request(prompt=prompts[i], max_new_tokens=m)
        for i, m in enumerate((8, 2, 5, 1))]
engine.serve(reqs)
for i, r in enumerate(reqs):
    assert len(r.generated) == r.max_new_tokens
    assert r.generated == gen[i, :r.max_new_tokens].tolist()
    print(f"req{i} (budget {r.max_new_tokens}): {r.generated}")

# continuous batching: the same requests arrive STAGGERED and run through
# 2 recycled KV-pool slots — admitted the moment a slot frees, retired the
# step they finish, streamed token by token.  Outputs are bit-identical to
# the static path (the scheduler's parity oracle).
reqs = [Request(prompt=prompts[i], max_new_tokens=m, request_id=i,
                arrival_time=0.02 * i)
        for i, m in enumerate((8, 2, 5, 1))]
streamed = {}
engine.serve(reqs, continuous=True, max_batch=2,
             stream=lambda r, t: streamed.setdefault(r.request_id, []).append(t))
for i, r in enumerate(reqs):
    assert r.generated == gen[i, :r.max_new_tokens].tolist()
    assert streamed[i] == r.generated
    m = r.result.metrics
    print(f"req{i} (arrived {r.arrival_time:.2f}s) "
          f"ttft={m.ttft:.3f}s wait={m.queue_wait:.3f}s: {r.generated}")
print("OK")
