"""Subprocess worker: compiles the 2D transformer under a given SP method on
N simulated devices and reports HLO-derived communication volume, collective
counts, memory analysis, and (optional) wall time per step.

Invoked by the benchmark drivers with
XLA_FLAGS=--xla_force_host_platform_device_count=<N>; prints one JSON line.
"""
import json
import sys
import time


def main():
    cfg_json = json.loads(sys.argv[1])
    import jax
    import jax.numpy as jnp
    from repro.analysis.roofline import parse_collectives
    from repro.models.transformer2d import (T2DConfig, init_t2d,
                                            make_spmd_forward, t2d_loss,
                                            forward)

    n = cfg_json["devices"]
    mode = cfg_json["mode"]
    b, t, s = cfg_json["batch"], cfg_json["temporal"], cfg_json["spatial"]
    cfg = T2DConfig(name="bench", n_layers=cfg_json.get("layers", 4),
                    d_model=cfg_json.get("d_model", 128),
                    n_heads=cfg_json.get("heads", 8),
                    d_ff=cfg_json.get("d_ff", 256),
                    in_dim=cfg_json.get("in_dim", 16),
                    modulate=cfg_json.get("modulate", True),
                    n_kv_heads=cfg_json.get("n_kv_heads"),
                    dtype=jnp.float32)
    from repro.core.compat import make_mesh
    if mode in ("hybrid", "layout2d"):
        # 2D SP process grid (outer DCN factor major) — launch.mesh
        outer = cfg_json.get("sp_outer") or 2
        mesh = make_mesh((outer, n // outer), ("sp_out", "sp_in"))
    else:
        mesh = make_mesh((n,), ("model",))

    params = init_t2d(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, s, cfg.in_dim))
    tt = jax.random.uniform(jax.random.PRNGKey(2), (b,))

    overlap = cfg_json.get("overlap")    # dsp only: decomposed switches
    if mode == "layout2d":
        # first-class 2D layouts: the planned Schedule2D drives forward2d
        # on the ("sp_out", "sp_in") grid — per-axis sub-mesh switches
        from repro.models.transformer2d import forward2d
        fn = jax.jit(lambda p, xx, t_: forward2d(p, xx, t_, cfg, mesh=mesh,
                                                 remat=False))
    elif cfg_json.get("grad"):
        fwd = make_spmd_forward(cfg, mesh, mode=mode, backend="ref",
                                remat=True, overlap=overlap)

        def step(p, x, tt):
            def loss(p):
                out = fwd(p, x, tt)
                return jnp.mean(out.astype(jnp.float32) ** 2)
            return jax.grad(loss)(p)
        fn = jax.jit(step)
    else:
        fn = jax.jit(make_spmd_forward(cfg, mesh, mode=mode, backend="ref",
                                       overlap=overlap))

    lowered = fn.lower(params, x, tt)
    compiled = lowered.compile()
    stats = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()

    out = {
        "mode": mode, "devices": n,
        "collective_bytes_per_dev": stats.bytes_per_device,
        "collective_count": stats.count,
        "by_kind": stats.by_kind,
        "by_kind_count": stats.by_kind_count,
        "temp_bytes": mem.temp_size_in_bytes,
        "arg_bytes": mem.argument_size_in_bytes,
    }
    if cfg_json.get("time"):
        r = fn(params, x, tt)
        jax.block_until_ready(r)
        t0 = time.monotonic()
        reps = cfg_json.get("reps", 3)
        for _ in range(reps):
            r = fn(params, x, tt)
        jax.block_until_ready(r)
        out["us_per_call"] = (time.monotonic() - t0) / reps * 1e6
    print(json.dumps(out))


if __name__ == "__main__":
    main()
