"""Serving load benchmark: static vs continuous batching on one arrival
trace (the serving trajectory's first datapoint).

A worker subprocess simulates an N-device mesh (default 8, data=1 so the
model axis carries DSP sequence parallelism), builds one sharded
ServingEngine, and replays the SAME synthetic Poisson arrival trace through
both batching policies:

* **static**  — ``serving.scheduler.replay_static``: FIFO chunks of
  ``max_batch``, each chunk waits for its last arrival, prefills together,
  decodes in lockstep until its slowest row finishes.
* **continuous** — ``serving.scheduler.ContinuousScheduler``: per-request
  admission the moment a slot frees, per-step retirement, slot reuse.

Both arms run the same jitted prefill/decode cells (warmed up before
timing), the same greedy decode, the same wall clock — only the batching
policy differs, and the worker asserts their tokens are IDENTICAL before
reporting any numbers.  Decode budgets are deliberately heterogeneous
(uniform over [min, max]): lockstep waste and queue-wait are exactly what
continuous batching exists to remove.

A second trace targets the PAGED tier (``serving.scheduler.PagedScheduler``):
every prompt opens with the same shared system prefix and decode budgets are
long-tailed, the workload prefix caching + chunked prefill exist for.  The
same trace runs through the slot scheduler (re-prefills the shared prefix
every admission) and the paged scheduler (radix-tree hits skip it); the
worker asserts token parity against the static oracle and reports the
prefill-compute saving (prefix-hit tokens / prompt tokens) alongside p99
TTFT — the full run asserts the saving clears 30%.

Writes ``BENCH_serving.json`` at the repo root: per-arm throughput tok/s,
p50/p99 TTFT and TPOT, queue wait, slot occupancy, plus the ratios, and the
``prefix_trace`` block (slot vs paged + prefill savings).  Run standalone
(``python benchmarks/serving_load.py [--steps 2]``) or via
``benchmarks/run.py serving_load``.  ``--steps`` caps the decode budgets —
CI smokes the JSON schema (both traces) with ``--steps 2``.
"""
import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
SRC = os.path.join(ROOT, "src")

SUMMARY_KEYS = (            # the schema CI smoke-checks (don't rot silently)
    "throughput_tok_s", "ttft_p50_s", "ttft_p99_s", "tpot_p50_s",
    "tpot_p99_s", "queue_wait_p50_s", "queue_wait_p99_s", "slot_occupancy",
    "tokens_generated", "decode_steps", "slots_allocated", "elapsed_s",
)
PAGED_KEYS = (              # extra gauges only the paged arm populates
    "prefix_hit_rate", "prefix_hit_tokens", "prefill_chunk_steps",
    "blocks_in_use", "blocks_free", "peak_blocks_in_use",
)


def _worker(cfg: dict) -> None:
    """Runs inside the simulated-mesh subprocess; prints one JSON line."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.topology import Topology
    from repro.models.lm import LMConfig, init_lm
    from repro.parallel.partition import ParallelPlan
    from repro.serving.engine import Request, ServingEngine, _submesh
    from repro.serving.kv_pool import KVPool
    from repro.serving.scheduler import (ContinuousScheduler, PagedScheduler,
                                         replay_static)

    n_dev = cfg["devices"]
    max_batch = cfg["max_batch"]
    n_req = cfg["n_requests"]
    plen = cfg["prompt_len"]
    prefix_len = cfg.get("prefix_len", 0)
    block_size = cfg.get("block_size", 16)
    rng = np.random.RandomState(0)
    if cfg.get("tail") == "longtail":
        # long-tailed budgets: most requests finish fast, a few run long —
        # the regime where chunked prefill keeps the pool's decoders moving
        budgets = np.clip(cfg["min_new"]
                          + np.round(rng.exponential(6.0, n_req)).astype(int),
                          cfg["min_new"], cfg["max_new"])
    else:
        budgets = rng.randint(cfg["min_new"], cfg["max_new"] + 1, size=n_req)
    max_len = plen + int(budgets.max())
    max_len += (-max_len) % max(n_dev, 1)     # seq-sharded divisibility
    if cfg.get("paged"):
        max_len = int(max_len + (-max_len) % np.lcm(block_size,
                                                    max(n_dev, 1)))

    mcfg = LMConfig(name="bench-serve", n_layers=2, d_model=64, n_heads=8,
                    n_kv_heads=4, head_dim=16, d_ff=128, vocab=96,
                    dtype=jnp.float32)
    params = init_lm(jax.random.PRNGKey(0), mcfg)
    mesh = _submesh(n_dev, 1) if n_dev > 1 else None
    eng = ServingEngine(params, mcfg, max_len=max_len, mesh=mesh,
                        plan=ParallelPlan(mode="dsp" if mesh is not None
                                          else "none"),
                        topology=(Topology.flat_ici(n_dev)
                                  if n_dev > 1 else None))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (n_req, plen), 0,
                                 mcfg.vocab)
    if prefix_len:
        # every request opens with the SAME system prefix (the prefix-cache
        # workload); suffixes stay per-request random
        shared = jax.random.randint(jax.random.PRNGKey(2), (prefix_len,), 0,
                                    mcfg.vocab)
        prompts = jnp.concatenate(
            [jnp.broadcast_to(shared, (n_req, prefix_len)),
             prompts[:, prefix_len:]], axis=1)

    # -- warm every jit cache both arms will hit (compiles out of the timed
    # region: batch-1 + chunk prefill, pool + chunk decode) --------------------
    lg, caches1 = eng._prefill(prompts[:1])
    jax.block_until_ready(eng._decode(jnp.argmax(lg[:, -1], -1)[:, None],
                                      caches1))
    lgc, cachesc = eng._prefill(prompts[:max_batch])
    jax.block_until_ready(eng._decode(jnp.argmax(lgc[:, -1], -1)[:, None],
                                      cachesc))
    # a real KVPool so the warmed/calibrated decode signature (shapes AND
    # placement) is exactly the one the scheduler will run
    pool_caches = KVPool(mcfg, max_batch, max_len, mesh=mesh,
                         plan=eng.plan).caches
    tok = jnp.zeros((max_batch, 1), jnp.int32)
    jax.block_until_ready(eng._decode(tok, pool_caches)[0])

    # -- calibrate the arrival trace to the measured decode step (the pool's
    # REAL signature: per-slot pos, mesh placement) ---------------------------
    t0 = time.monotonic()
    reps = 10
    for _ in range(reps):
        lg, pool_caches = eng._decode(tok, pool_caches)
        jax.block_until_ready(lg)
    t_step = (time.monotonic() - t0) / reps
    mean_gap = cfg["gap_steps"] * t_step
    arrivals = np.cumsum(rng.exponential(mean_gap, size=n_req))
    arrivals[0] = 0.0

    def make_requests():
        return [Request(prompt=prompts[i], max_new_tokens=int(budgets[i]),
                        arrival_time=float(arrivals[i]), request_id=i)
                for i in range(n_req)]

    static_reqs, static_metrics = replay_static(eng, make_requests(),
                                                max_batch=max_batch)
    cont_reqs = make_requests()
    sched = ContinuousScheduler(eng, max_batch=max_batch)
    sched.run(cont_reqs)
    if mesh is not None:
        sched.pool.assert_on_mesh()

    by_id = {r.request_id: r for r in static_reqs}
    parity = all(by_id[r.request_id].generated == r.generated
                 for r in cont_reqs)
    assert parity, "continuous tokens diverged from the static oracle"

    out = {
        "config": {**cfg, "max_len": max_len, "t_step_s": t_step,
                   "budgets": budgets.tolist(),
                   "arrivals_s": np.round(arrivals, 4).tolist()},
        "parity": parity,
        "static": static_metrics.summary(),
        "continuous": sched.metrics.summary(),
    }

    if cfg.get("paged"):
        chunk = cfg.get("prefill_chunk", block_size)
        # warm the paged jit caches (chunk cell per width + block-layout
        # decode) on a throwaway scheduler so compiles stay out of the
        # timed trace, mirroring the slot arms' warmup above
        warm = [Request(prompt=prompts[i], max_new_tokens=2, request_id=i)
                for i in range(min(2, n_req))]
        PagedScheduler(eng, max_batch=max_batch, block_size=block_size,
                       prefill_chunk=chunk).run(warm)

        paged_reqs = make_requests()
        psched = PagedScheduler(eng, max_batch=max_batch,
                                block_size=block_size, prefill_chunk=chunk)
        psched.run(paged_reqs)
        if mesh is not None:
            psched.pool.assert_on_mesh()
        assert all(by_id[r.request_id].generated == r.generated
                   for r in paged_reqs), (
            "paged tokens diverged from the static oracle")
        ps = psched.metrics.summary()
        out["paged"] = ps
        # prefill compute ~ tokens pushed through the prefill/chunk cells:
        # the slot arm recomputes every prompt token, the paged arm skips
        # the radix-tree hits
        out["prefill"] = {
            "slot_prefill_tokens": n_req * plen,
            "paged_prefill_tokens": n_req * plen - ps["prefix_hit_tokens"],
            "saved_frac": ps["prefix_hit_tokens"] / float(n_req * plen),
        }
    print(json.dumps(out))


def run_trace(devices: int, *, n_requests=16, max_batch=4, prompt_len=16,
              min_new=2, max_new=32, gap_steps=1.5, **extra) -> dict:
    """Heterogeneous budgets (uniform [min_new, max_new]) are the point:
    static batching decodes every chunk to its SLOWEST row while continuous
    retires and refills per step — the gap is the lockstep waste.  ``extra``
    passes the prefix-trace knobs through to the worker (``prefix_len``,
    ``block_size``, ``prefill_chunk``, ``paged``, ``tail``)."""
    cfg = dict(devices=devices, n_requests=n_requests, max_batch=max_batch,
               prompt_len=prompt_len, min_new=min_new, max_new=max_new,
               gap_steps=gap_steps, **extra)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--run-worker",
         json.dumps(cfg)],
        env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"serving_load worker failed:\n"
                           f"{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main(argv=None):
    if ROOT not in sys.path:        # standalone `python benchmarks/...` runs
        sys.path.insert(0, ROOT)
    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=0,
                    help="cap decode budgets at this many tokens "
                    "(smoke mode; 0 = full trace)")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_serving.json"))
    args = ap.parse_args([] if argv is None else argv)

    smoke = 0 < args.steps < 8
    kw = {}
    pkw = dict(n_requests=16, max_batch=4, prompt_len=48, prefix_len=32,
               min_new=2, max_new=32, block_size=16, prefill_chunk=16,
               paged=True, tail="longtail")
    if smoke:
        kw = dict(n_requests=4, max_batch=2, min_new=max(args.steps, 2),
                  max_new=max(args.steps, 2))
        pkw.update(n_requests=3, max_batch=2, prompt_len=32, prefix_len=16,
                   min_new=max(args.steps, 2), max_new=max(args.steps, 2))
    elif args.steps:
        kw = dict(max_new=args.steps)
        pkw.update(max_new=args.steps)
    res = run_trace(args.devices, **kw)
    pres = run_trace(args.devices, **pkw)

    st, ct = res["static"], res["continuous"]
    pg = pres["paged"]
    for arm, s in (("static", st), ("continuous", ct),
                   ("prefix/slot", pres["continuous"]), ("prefix/paged", pg)):
        missing = [k for k in SUMMARY_KEYS if k not in s]
        assert not missing, f"{arm} summary lost keys: {missing}"
    missing = [k for k in PAGED_KEYS if k not in pg]
    assert not missing, f"paged summary lost keys: {missing}"
    res["ratios"] = {
        "throughput_x": (ct["throughput_tok_s"] / st["throughput_tok_s"]
                         if st["throughput_tok_s"] else None),
        "ttft_p99_x": (st["ttft_p99_s"] / ct["ttft_p99_s"]
                       if ct["ttft_p99_s"] else None),
    }
    res["prefix_trace"] = {
        "config": pres["config"],
        "slot": pres["continuous"],
        "paged": pg,
        "prefill": pres["prefill"],
    }
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)

    emit("serving_load.static",
         st["ttft_p99_s"] * 1e6 if st["ttft_p99_s"] else None,
         f"thru={st['throughput_tok_s']:.1f}tok/s "
         f"occ={st['slot_occupancy']:.2f}")
    emit("serving_load.continuous",
         ct["ttft_p99_s"] * 1e6 if ct["ttft_p99_s"] else None,
         f"thru={ct['throughput_tok_s']:.1f}tok/s "
         f"occ={ct['slot_occupancy']:.2f}")
    emit("serving_load.ratio", None,
         f"thru_x={res['ratios']['throughput_x']:.2f} "
         f"ttft_p99_x={res['ratios']['ttft_p99_x']:.2f}")
    saved = res["prefix_trace"]["prefill"]["saved_frac"]
    emit("serving_load.paged",
         pg["ttft_p99_s"] * 1e6 if pg["ttft_p99_s"] else None,
         f"thru={pg['throughput_tok_s']:.1f}tok/s "
         f"hit={pg['prefix_hit_rate'] or 0:.2f} "
         f"chunks={pg['prefill_chunk_steps']}")
    emit("serving_load.prefix_savings", None,
         f"prefill_saved={saved:.0%} "
         f"({res['prefix_trace']['prefill']['paged_prefill_tokens']}"
         f"/{res['prefix_trace']['prefill']['slot_prefill_tokens']} tok)")

    if not smoke:
        assert ct["throughput_tok_s"] > st["throughput_tok_s"], (
            "continuous batching must beat static throughput", res["ratios"])
        assert ct["ttft_p99_s"] < st["ttft_p99_s"], (
            "continuous batching must beat static p99 TTFT", res["ratios"])
        assert saved >= 0.30, (
            "prefix cache must save >= 30% of prefill compute on the "
            "shared-prefix trace", res["prefix_trace"]["prefill"])
    print(f"# wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--run-worker":
        _worker(json.loads(sys.argv[2]))
    else:
        main(sys.argv[1:])
