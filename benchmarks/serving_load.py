"""Serving load benchmark: static vs continuous batching on one arrival
trace (the serving trajectory's first datapoint).

A worker subprocess simulates an N-device mesh (default 8, data=1 so the
model axis carries DSP sequence parallelism), builds one sharded
ServingEngine, and replays the SAME synthetic Poisson arrival trace through
both batching policies:

* **static**  — ``serving.scheduler.replay_static``: FIFO chunks of
  ``max_batch``, each chunk waits for its last arrival, prefills together,
  decodes in lockstep until its slowest row finishes.
* **continuous** — ``serving.scheduler.ContinuousScheduler``: per-request
  admission the moment a slot frees, per-step retirement, slot reuse.

Both arms run the same jitted prefill/decode cells (warmed up before
timing), the same greedy decode, the same wall clock — only the batching
policy differs, and the worker asserts their tokens are IDENTICAL before
reporting any numbers.  Decode budgets are deliberately heterogeneous
(uniform over [min, max]): lockstep waste and queue-wait are exactly what
continuous batching exists to remove.

Writes ``BENCH_serving.json`` at the repo root: per-arm throughput tok/s,
p50/p99 TTFT and TPOT, queue wait, slot occupancy, plus the ratios.  Run
standalone (``python benchmarks/serving_load.py [--steps 2]``) or via
``benchmarks/run.py serving_load``.  ``--steps`` caps the decode budgets —
CI smokes the JSON schema with ``--steps 2``.
"""
import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
SRC = os.path.join(ROOT, "src")

SUMMARY_KEYS = (            # the schema CI smoke-checks (don't rot silently)
    "throughput_tok_s", "ttft_p50_s", "ttft_p99_s", "tpot_p50_s",
    "tpot_p99_s", "queue_wait_p50_s", "queue_wait_p99_s", "slot_occupancy",
    "tokens_generated", "decode_steps", "slots_allocated", "elapsed_s",
)


def _worker(cfg: dict) -> None:
    """Runs inside the simulated-mesh subprocess; prints one JSON line."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.topology import Topology
    from repro.models.lm import LMConfig, init_lm
    from repro.parallel.partition import ParallelPlan
    from repro.serving.engine import Request, ServingEngine, _submesh
    from repro.serving.kv_pool import KVPool
    from repro.serving.scheduler import ContinuousScheduler, replay_static

    n_dev = cfg["devices"]
    max_batch = cfg["max_batch"]
    n_req = cfg["n_requests"]
    plen = cfg["prompt_len"]
    rng = np.random.RandomState(0)
    budgets = rng.randint(cfg["min_new"], cfg["max_new"] + 1, size=n_req)
    max_len = plen + int(budgets.max())
    max_len += (-max_len) % max(n_dev, 1)     # seq-sharded divisibility

    mcfg = LMConfig(name="bench-serve", n_layers=2, d_model=64, n_heads=8,
                    n_kv_heads=4, head_dim=16, d_ff=128, vocab=96,
                    dtype=jnp.float32)
    params = init_lm(jax.random.PRNGKey(0), mcfg)
    mesh = _submesh(n_dev, 1) if n_dev > 1 else None
    eng = ServingEngine(params, mcfg, max_len=max_len, mesh=mesh,
                        plan=ParallelPlan(mode="dsp" if mesh is not None
                                          else "none"),
                        topology=(Topology.flat_ici(n_dev)
                                  if n_dev > 1 else None))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (n_req, plen), 0,
                                 mcfg.vocab)

    # -- warm every jit cache both arms will hit (compiles out of the timed
    # region: batch-1 + chunk prefill, pool + chunk decode) --------------------
    lg, caches1 = eng._prefill(prompts[:1])
    jax.block_until_ready(eng._decode(jnp.argmax(lg[:, -1], -1)[:, None],
                                      caches1))
    lgc, cachesc = eng._prefill(prompts[:max_batch])
    jax.block_until_ready(eng._decode(jnp.argmax(lgc[:, -1], -1)[:, None],
                                      cachesc))
    # a real KVPool so the warmed/calibrated decode signature (shapes AND
    # placement) is exactly the one the scheduler will run
    pool_caches = KVPool(mcfg, max_batch, max_len, mesh=mesh,
                         plan=eng.plan).caches
    tok = jnp.zeros((max_batch, 1), jnp.int32)
    jax.block_until_ready(eng._decode(tok, pool_caches)[0])

    # -- calibrate the arrival trace to the measured decode step (the pool's
    # REAL signature: per-slot pos, mesh placement) ---------------------------
    t0 = time.monotonic()
    reps = 10
    for _ in range(reps):
        lg, pool_caches = eng._decode(tok, pool_caches)
        jax.block_until_ready(lg)
    t_step = (time.monotonic() - t0) / reps
    mean_gap = cfg["gap_steps"] * t_step
    arrivals = np.cumsum(rng.exponential(mean_gap, size=n_req))
    arrivals[0] = 0.0

    def make_requests():
        return [Request(prompt=prompts[i], max_new_tokens=int(budgets[i]),
                        arrival_time=float(arrivals[i]), request_id=i)
                for i in range(n_req)]

    static_reqs, static_metrics = replay_static(eng, make_requests(),
                                                max_batch=max_batch)
    cont_reqs = make_requests()
    sched = ContinuousScheduler(eng, max_batch=max_batch)
    sched.run(cont_reqs)
    if mesh is not None:
        sched.pool.assert_on_mesh()

    by_id = {r.request_id: r for r in static_reqs}
    parity = all(by_id[r.request_id].generated == r.generated
                 for r in cont_reqs)
    assert parity, "continuous tokens diverged from the static oracle"

    out = {
        "config": {**cfg, "max_len": max_len, "t_step_s": t_step,
                   "budgets": budgets.tolist(),
                   "arrivals_s": np.round(arrivals, 4).tolist()},
        "parity": parity,
        "static": static_metrics.summary(),
        "continuous": sched.metrics.summary(),
    }
    print(json.dumps(out))


def run_trace(devices: int, *, n_requests=16, max_batch=4, prompt_len=16,
              min_new=2, max_new=32, gap_steps=1.5) -> dict:
    """Heterogeneous budgets (uniform [min_new, max_new]) are the point:
    static batching decodes every chunk to its SLOWEST row while continuous
    retires and refills per step — the gap is the lockstep waste."""
    cfg = dict(devices=devices, n_requests=n_requests, max_batch=max_batch,
               prompt_len=prompt_len, min_new=min_new, max_new=max_new,
               gap_steps=gap_steps)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--run-worker",
         json.dumps(cfg)],
        env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"serving_load worker failed:\n"
                           f"{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main(argv=None):
    if ROOT not in sys.path:        # standalone `python benchmarks/...` runs
        sys.path.insert(0, ROOT)
    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=0,
                    help="cap decode budgets at this many tokens "
                    "(smoke mode; 0 = full trace)")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_serving.json"))
    args = ap.parse_args([] if argv is None else argv)

    smoke = 0 < args.steps < 8
    kw = {}
    if smoke:
        kw = dict(n_requests=4, max_batch=2, min_new=max(args.steps, 2),
                  max_new=max(args.steps, 2))
    elif args.steps:
        kw = dict(max_new=args.steps)
    res = run_trace(args.devices, **kw)

    st, ct = res["static"], res["continuous"]
    for arm, s in (("static", st), ("continuous", ct)):
        missing = [k for k in SUMMARY_KEYS if k not in s]
        assert not missing, f"{arm} summary lost keys: {missing}"
    res["ratios"] = {
        "throughput_x": (ct["throughput_tok_s"] / st["throughput_tok_s"]
                         if st["throughput_tok_s"] else None),
        "ttft_p99_x": (st["ttft_p99_s"] / ct["ttft_p99_s"]
                       if ct["ttft_p99_s"] else None),
    }
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)

    emit("serving_load.static",
         st["ttft_p99_s"] * 1e6 if st["ttft_p99_s"] else None,
         f"thru={st['throughput_tok_s']:.1f}tok/s "
         f"occ={st['slot_occupancy']:.2f}")
    emit("serving_load.continuous",
         ct["ttft_p99_s"] * 1e6 if ct["ttft_p99_s"] else None,
         f"thru={ct['throughput_tok_s']:.1f}tok/s "
         f"occ={ct['slot_occupancy']:.2f}")
    emit("serving_load.ratio", None,
         f"thru_x={res['ratios']['throughput_x']:.2f} "
         f"ttft_p99_x={res['ratios']['ttft_p99_x']:.2f}")

    if not smoke:
        assert ct["throughput_tok_s"] > st["throughput_tok_s"], (
            "continuous batching must beat static throughput", res["ratios"])
        assert ct["ttft_p99_s"] < st["ttft_p99_s"], (
            "continuous batching must beat static p99 TTFT", res["ratios"])
    print(f"# wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--run-worker":
        _worker(json.loads(sys.argv[2]))
    else:
        main(sys.argv[1:])
