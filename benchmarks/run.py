"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  SPMD measurements run in
subprocesses with their own simulated device counts; this process keeps the
1-device default.

  comm_volume     Table 2/3   per-layer comm volume per method (HLO-measured)
  e2e_throughput  Figure 5    0.5M-4M token throughput model
  scaling         Figures 6/7 weak/strong scaling
  latency_fig8    Figure 8    inference latency
  memory_fig9     Figure 9    per-device memory per method
  kernels_micro   —           Pallas kernel microbenches + roofline
  serving_load    —           static vs continuous batching on one arrival
                              trace (writes BENCH_serving.json)
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (comm_volume, e2e_throughput, kernels_micro,
                            latency_fig8, memory_fig9, scaling, serving_load)
    mods = [("comm_volume", comm_volume), ("e2e_throughput", e2e_throughput),
            ("scaling", scaling), ("latency_fig8", latency_fig8),
            ("memory_fig9", memory_fig9), ("kernels_micro", kernels_micro),
            ("serving_load", serving_load)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failed = []
    for name, mod in mods:
        if only and only != name:
            continue
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name},,FAILED:{e!r}")
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
