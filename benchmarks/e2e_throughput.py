"""Paper Figure 5: end-to-end throughput, 0.5M-4M tokens on a 128-way pod.

CPU hosts cannot measure TPU wall time, so the figure is reproduced as a
cost model with the Table-3 volume formulas — which benchmarks/comm_volume.py
verifies against compiled HLO at ratio 1.00 — evaluated at the paper's
Table-5 parallel settings: sequence length (tokens per video sample) scales
0.5M -> 4M while the sequence-parallel degree scales 2 -> 16 (the minimum
that fits) and data parallel covers the rest of the 128 chips.

    per-device comm/layer: dsp 2M/N | ulysses 4M/N | ring 2M | megatron 8M
    M = seq_tokens * d_model * 2 bytes (one sample per SP group)

Reported: FLOPS/chip per method per point + the 0.5M->4M FLOPS drop (paper:
DSP drops <= 23%, baselines >= 40%).
"""
from benchmarks.common import emit
from repro.analysis.roofline import PEAK_FLOPS
from repro.core.topology import ICI_BW

CHIPS = 128
PARAMS = 670e6
D_MODEL = 1152
LAYERS = 28
SPATIAL = 4096

# Table 5 (720M row): (name, temporal, sp_degree)
POINTS = [("0.5m", 128, 2), ("1m", 256, 4), ("2m", 512, 8), ("4m", 1024, 16)]


def vol_per_device(mode: str, m_bytes: float, n: int) -> float:
    return {"dsp": 2 * m_bytes / n, "ulysses": 4 * m_bytes / n,
            "ring": 2 * m_bytes, "megatron": 8 * m_bytes}[mode]


def main():
    flops_per_chip = {}
    for name, temporal, sp in POINTS:
        seq = temporal * SPATIAL
        tokens_per_step = (CHIPS // sp) * seq        # one sample per SP group
        m = seq * D_MODEL * 2                        # bf16 activation
        compute = 3 * 6 * PARAMS * tokens_per_step / (CHIPS * PEAK_FLOPS)
        row = {}
        for mode in ("dsp", "ulysses", "ring", "megatron"):
            comm = vol_per_device(mode, m, sp) * LAYERS * 3 / ICI_BW
            step = compute + comm
            row[mode] = 6 * PARAMS * tokens_per_step / step / CHIPS
        flops_per_chip[name] = row
        emit(f"fig5/flops_per_chip/{name}", None,
             ";".join(f"{k}={v:.3e}" for k, v in row.items())
             + f";dsp_vs_ulysses={row['dsp']/row['ulysses']:.3f}x"
             + f";dsp_vs_megatron={row['dsp']/row['megatron']:.2f}x")
    for mode in ("dsp", "ulysses", "ring", "megatron"):
        drop = 1 - flops_per_chip["4m"][mode] / flops_per_chip["0.5m"][mode]
        emit(f"fig5/flops_drop/{mode}", None, f"drop_0.5m_to_4m={drop:.2%}")
    # headline claims
    assert (1 - flops_per_chip["4m"]["dsp"] /
            flops_per_chip["0.5m"]["dsp"]) < 0.23
    for mode in ("ring", "megatron"):
        assert (1 - flops_per_chip["4m"][mode] /
                flops_per_chip["0.5m"][mode]) > 0.40, mode


if __name__ == "__main__":
    main()
