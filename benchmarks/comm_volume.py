"""Paper Table 2 + Table 3: per-layer communication volume of each SP method
on the 2D transformer — analytic model AND measured from compiled HLO on a
simulated 8-device ring.

Table 3 claims (activation size M, N devices):
    DSP 2M/N | Ulysses 4M/N | Megatron-SP 8M | Ring 2M

All analytic numbers are priced with the SAME constant the planner and the
schedule executor use (``repro.core.dsp.comm_volume_bytes``: switch = M/N,
gather = M); for DSP the script additionally reports the PLANNED volume from
the model's own solved schedule (``transformer2d.dsp_schedule``) next to the
measured HLO bytes — planned-vs-measured is the executor's contract — and
the planned training ROUND TRIP: forward and backward legs priced separately
(the backward is planned by the joint DP, not assumed to mirror the
forward; see docs/architecture.md §2.4).

Since PR 5 the scanned LM/enc-dec executors RUN non-mirrored joint plans
(per-period custom_vjp boundaries), so the script also reports the
EXECUTED scanned round trip — the joint schedule the scanned-LM train step
compiles, priced per leg on the flat-ICI and ICIxDCN fabrics, with the
executed per-leg collective counts from the executor's own accounting.

PR 6 adds the comm-compute overlap row: the scanned dsp forward with every
planned switch decomposed into per-shard collective-permute hops
(``core.overlap.overlapped_switch``), wall-clocked against the synchronous
executor on the 8-device sim, with the planner's exposed/hidden seconds
split per fabric and a ``notes`` field explaining the result.

Everything lands in ``BENCH_comm.json`` at the repo root (planned vs
measured bytes/seconds per mode and fabric) so the trajectory is tracked
across PRs; CI smokes the schema with ``--quick`` (dsp-only measurement +
the overlap row).
"""
import argparse
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks.common import spmd_measure, emit
from repro.core.dsp import per_device_bytes

N = 8
LAYERS = 4          # 2 layer-pairs
MODES = ["dsp", "ulysses", "ulysses_fused", "ring", "megatron"]

# benchmark mode -> strategy constant (core.topology.STRATEGIES); the fused
# ulysses variant moves the same bytes in half the launches
_STRATEGY_OF_MODE = {"dsp": "dsp", "ulysses": "ulysses",
                     "ulysses_fused": "ulysses", "ring": "ring",
                     "megatron": "megatron", "hybrid": "hybrid"}


def analytic_bytes(mode: str, m_bytes: float, n: int, *, kv_bytes=None,
                   kv_heads=None, outer=1) -> float:
    """Per-layer analytic volume, routed through the ONE shared constant
    (``core.dsp.per_device_bytes``) that the strategy DP and the mode
    implementations (``core.ulysses.attention_bytes``,
    ``core.ring.stream_bytes``, ``core.megatron_sp.block_bytes``) also
    price from.  ``per_device_bytes`` is per STAGE; a 2D-transformer layer
    runs megatron's AG/RS wrapping in BOTH blocks (x2 = Table 3's 8M),
    every other mode pays its collectives once per layer."""
    v = per_device_bytes(_STRATEGY_OF_MODE[mode], m_bytes, n,
                         kv_bytes=kv_bytes, kv_heads=kv_heads, outer=outer)
    return 2 * v if mode == "megatron" else v


def _fabrics():
    from repro.core.topology import Topology
    return (("ici", Topology.flat_ici(N)),
            ("ici_dcn", Topology.multihost(2, N // 2)))


def _leg_seconds(sched) -> dict:
    out = {}
    for label, topo in _fabrics():
        rs = sched.roundtrip_seconds(topo)
        out[label] = {"fwd_seconds": rs.fwd, "bwd_seconds": rs.bwd,
                      "roundtrip_seconds": rs.total,
                      "bottleneck_gbps": topo.bottleneck_bandwidth / 1e9}
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="measure only the dsp mode (CI schema smoke)")
    ap.add_argument("--out", default=os.path.join(_ROOT, "BENCH_comm.json"))
    args = ap.parse_args(argv)

    b, t, s, d = 2, 16, 32, 128
    m_bytes = b * t * s * d * 4          # f32 activation size
    pairs = LAYERS // 2
    record = {"config": {"devices": N, "layers": LAYERS, "batch": b,
                         "temporal": t, "spatial": s, "d_model": d},
              "modes": {}}
    rows = {}
    modes = ["dsp"] if args.quick else MODES
    for mode in modes:
        r = spmd_measure(N, mode, batch=b, temporal=t, spatial=s,
                         layers=LAYERS, d_model=d, modulate=False)
        per_layer = r["collective_bytes_per_dev"] / pairs
        rows[mode] = per_layer
        pred = analytic_bytes(mode, m_bytes, N)
        record["modes"][mode] = {
            "measured_bytes_per_layer": per_layer,
            "analytic_bytes_per_layer": pred,
            "ratio": per_layer / max(pred, 1),
            "counts": r["by_kind_count"],
        }
        emit(f"table3/comm_volume/{mode}", None,
             f"measured_bytes_per_layer={per_layer:.0f};"
             f"analytic={pred:.0f};ratio={per_layer/max(pred, 1):.2f};"
             f"counts={r['by_kind_count']}")

    # planned-vs-measured for DSP: the model's own solved schedule must
    # price what the compiled HLO actually moves
    from repro.models.transformer2d import T2DConfig, dsp_schedule
    import jax.numpy as jnp
    cfg = T2DConfig(name="bench", n_layers=LAYERS, d_model=d, n_heads=8,
                    d_ff=256, in_dim=16, modulate=False, dtype=jnp.float32)
    psched = dsp_schedule(cfg, N, t_len=t, s_len=s, batch=b)
    planned_total = psched.schedule.per_device_bytes(N)
    measured_total = rows["dsp"] * pairs
    emit("table3/planned_vs_measured/dsp", None,
         f"planned_bytes={planned_total:.0f};measured={measured_total:.0f};"
         f"ratio={measured_total/max(planned_total, 1):.2f};"
         f"planned_switches={psched.schedule.n_switches()}")

    # planned SECONDS next to planned bytes: the same schedule priced on two
    # modeled fabrics (flat ICI ring vs the SP group spanning 2 hosts over
    # DCN) — bytes are identical, time is not, which is exactly why the
    # planner optimises seconds on a Topology
    for label, topo in _fabrics():
        secs = psched.schedule.per_device_seconds(topo)
        emit(f"table3/planned_seconds/{label}", None,
             f"planned_bytes={planned_total:.0f};"
             f"planned_seconds={secs:.3e};"
             f"bottleneck_gbps={topo.bottleneck_bandwidth/1e9:.1f}")

    # the ROUND TRIP: training pays the backward's collectives too.  The
    # joint fwd+bwd planner (core.plan.plan_joint) prices the backward as
    # its own stage graph; on this symmetric model the mirrored plan is
    # optimal (bwd == fwd volumes) and the planner must keep it.
    jsched = dsp_schedule(cfg, N, t_len=t, s_len=s, batch=b,
                          joint=True).schedule
    rb = jsched.roundtrip_bytes(N)
    emit("table3/planned_roundtrip/bytes", None,
         f"fwd_bytes={rb.fwd:.0f};bwd_bytes={rb.bwd:.0f};"
         f"total={rb.total:.0f};bwd_mirrored={jsched.mirrored}")
    assert jsched.mirrored and rb.bwd == rb.fwd
    t2d_fabrics = _leg_seconds(jsched)
    for label, legs in t2d_fabrics.items():
        emit(f"table3/planned_roundtrip/{label}", None,
             f"fwd_seconds={legs['fwd_seconds']:.3e};"
             f"bwd_seconds={legs['bwd_seconds']:.3e};"
             f"roundtrip_seconds={legs['roundtrip_seconds']:.3e}")
    record["dsp"] = {
        "planned_bytes": planned_total,
        "measured_bytes": measured_total,
        "planned_switches": psched.schedule.n_switches(),
        "roundtrip": {"fwd_bytes": rb.fwd, "bwd_bytes": rb.bwd,
                      "total_bytes": rb.total,
                      "bwd_mirrored": jsched.mirrored},
        "fabrics": t2d_fabrics,
    }

    # the EXECUTED scanned round trip (PR 5): the joint schedule the
    # scanned-LM train step actually compiles — the scanned executors run
    # non-mirrored plans through per-period custom_vjp boundaries, so the
    # schedule priced below IS the schedule the train step executes (one
    # object; identity pinned by tests/test_hlo_collectives.py).  The
    # per-leg collective counts are the executor-structure ACCOUNTING
    # (exact for the executor path — t2d/synthetic scan — by the HLO tier;
    # the LM's hook path lowers the fused QKV switch as multiple smaller
    # all-to-alls, so its instruction counts differ even though the moved
    # bytes match), reported on both fabrics
    from repro.core.layout import from_mesh
    from repro.core.compat import make_mesh
    from repro.core.schedule import ScheduleExecutor
    from repro.models.lm import (LMConfig, dsp_schedule as lm_schedule,
                                 stage_period)
    lcfg = LMConfig(name="bench", n_layers=LAYERS, d_model=d, n_heads=8,
                    n_kv_heads=8, head_dim=d // 8, d_ff=2 * d, vocab=256,
                    dtype=jnp.float32)
    lsched = lm_schedule(lcfg, N, seq=t * s, batch=b, joint=True)
    lrb = lsched.roundtrip_bytes(N)
    ex = ScheduleExecutor(lsched.periodic(stage_period(lcfg)),
                          backend="auto",
                          ctx=from_mesh(make_mesh((1, 1),
                                                  ("data", "model"))))
    lm_fabrics = _leg_seconds(lsched)
    record["scanned_lm"] = {
        "planned_fwd_bytes": lrb.fwd,
        "planned_bwd_bytes": lrb.bwd,
        "bwd_mirrored": lsched.mirrored,
        "executed_bwd_dims_period": list(
            lsched.bwd_plan[:stage_period(lcfg)]),
        "accounted_fwd_collectives": ex.expected_collectives(lcfg.n_layers),
        "accounted_bwd_collectives": ex.expected_bwd_collectives(
            lcfg.n_layers),
        "fabrics": lm_fabrics,
    }
    for label, legs in lm_fabrics.items():
        emit(f"table3/scanned_roundtrip/{label}", None,
             f"fwd_seconds={legs['fwd_seconds']:.3e};"
             f"bwd_seconds={legs['bwd_seconds']:.3e};"
             f"roundtrip_seconds={legs['roundtrip_seconds']:.3e};"
             f"bwd_mirrored={lsched.mirrored}")

    # comm-compute OVERLAP (PR 6): the same scanned dsp forward with every
    # planned switch decomposed into n-1 collective-permute hops
    # (core.overlap.overlapped_switch), wall-clocked against the
    # synchronous executor on the 8-device sim, next to the planned
    # exposed/hidden split per fabric from the overlap-aware schedule.
    # Included in --quick so CI smokes the schema row.
    r_sync = spmd_measure(N, "dsp", batch=b, temporal=t, spatial=s,
                          layers=LAYERS, d_model=d, modulate=False,
                          time_it=True, reps=10)
    r_ov = spmd_measure(N, "dsp", batch=b, temporal=t, spatial=s,
                        layers=LAYERS, d_model=d, modulate=False,
                        time_it=True, reps=10, overlap="chunked")
    speedup = r_sync["us_per_call"] / max(r_ov["us_per_call"], 1e-9)
    overlap_fabrics = {}
    for label, topo in _fabrics():
        so = dsp_schedule(cfg, N, t_len=t, s_len=s, batch=b, topology=topo,
                          overlap="chunked").schedule
        overlap_fabrics[label] = {
            "planned_sync_seconds": so.per_device_seconds(topo),
            "planned_exposed_seconds": so.exposed_seconds(),
            "planned_hidden_seconds": so.hidden_comm_seconds(),
        }
        emit(f"table3/overlap/{label}", None,
             f"planned_sync_seconds="
             f"{overlap_fabrics[label]['planned_sync_seconds']:.3e};"
             f"exposed={overlap_fabrics[label]['planned_exposed_seconds']:.3e};"
             f"hidden={overlap_fabrics[label]['planned_hidden_seconds']:.3e}")
    if speedup >= 1.0:
        notes = (f"overlapped executor beats synchronous by "
                 f"{(speedup - 1) * 100:.1f}% wall-clock on the 8-device "
                 f"CPU sim")
    else:
        notes = (f"overlapped executor {1/max(speedup, 1e-9):.2f}x slower "
                 "wall-clock on this 8-device SIM: XLA:CPU lowers "
                 "collective-permute synchronously (no -start/-done "
                 "pipelining) and all 8 'devices' share one socket, so the "
                 "decomposition pays n-1 launch overheads and hides "
                 "nothing; the contract that the hops are independent and "
                 "SPAN the kernel (so an async backend pipelines them) is "
                 "pinned structurally in tests/test_hlo_collectives.py, "
                 "and the planned hidden seconds above quantify the win on "
                 "a modeled fabric")
    record["overlap"] = {
        "mode": "chunked",
        "sync_us_per_call": r_sync["us_per_call"],
        "overlap_us_per_call": r_ov["us_per_call"],
        "speedup": speedup,
        "counts": r_ov["by_kind_count"],
        "fabrics": overlap_fabrics,
        "notes": notes,
    }
    emit("table3/overlap/walltime", r_ov["us_per_call"],
         f"sync_us={r_sync['us_per_call']:.0f};"
         f"overlap_us={r_ov['us_per_call']:.0f};speedup={speedup:.2f};"
         f"counts={r_ov['by_kind_count']}")

    # megatron-SP planned SECONDS per fabric: it was the only mode reported
    # in bytes but never in Topology-priced time.  One t2d layer wraps both
    # blocks, each with an attention AND an MLP AG/RS pair = 4x
    # core.megatron_sp.block_seconds (alpha+beta ag + rs of the full M)
    from repro.core.megatron_sp import block_seconds
    meg_fabrics = {}
    for label, topo in _fabrics():
        meg_fabrics[label] = {
            "planned_seconds_per_layer": 4 * block_seconds(topo, m_bytes)}
        emit(f"table3/megatron_planned_seconds/{label}", None,
             f"planned_seconds_per_layer="
             f"{meg_fabrics[label]['planned_seconds_per_layer']:.3e}")
    record["megatron_sp"] = {
        "analytic_bytes_per_layer": analytic_bytes("megatron", m_bytes, N),
        "fabrics": meg_fabrics,
    }

    # ---- first-class 2D layouts row (TSP fold) ----------------------------
    # Two pinned facts about planning over dim PAIRS on the (2, 4) sp2d
    # grid.  (1) CONSERVATIVE: the 2D layout space contains the 1D plans as
    # its diagonal, so with the same entry/exit pinning the 2D DP is never
    # worse than the 1D DP on the same fabric — on this symmetric bench
    # instance it lands exactly on the embedded 1D plan (a joint a2a moves
    # M/N once; two per-axis a2as would move it twice, and the planner
    # knows it).  (2) ENABLING: on the TSP-fold instance (T=4, S=12,
    # 4 heads) NO dim extent divides the 8-way SP degree, so the 1D space
    # cannot shard the model at all (XLA would pad + involuntarily remat)
    # — dim-pair layouts split the factor across two dims and restore full
    # 8-way sharding, with the compiled forward2d HLO pinned to the
    # executor's per-axis accounting.  Runs under --quick.
    from repro.core.plan import (layout_allows, plan_cost_seconds,
                                 plan2d_cost_seconds, plan_switches_2d,
                                 plan_switches_dp)
    from repro.core.schedule import ScheduleExecutor2D
    from repro.launch.mesh import sp2d_topology
    from repro.models.transformer2d import dsp2d_schedule, stages2d
    grid2d = (2, N // 2)
    topo2d = sp2d_topology(*grid2d)          # == Topology.multihost(2, 4)
    bench_st = stages2d(cfg, t_len=t, s_len=s, batch=b)
    plan_1d = plan_switches_dp(bench_st, [1, 2, 3], n=N, initial=1, final=1,
                               topology=topo2d)
    secs_1d = plan_cost_seconds(bench_st, plan_1d, topo2d, initial=1,
                                final=1)
    plan_2d = plan_switches_2d(bench_st, [1, 2, 3], grid=grid2d, initial=1,
                               final=1, topology=topo2d)
    secs_2d = plan2d_cost_seconds(bench_st, plan_2d, topo2d, initial=1,
                                  final=1)
    assert secs_2d <= secs_1d, (
        f"2D plan space contains the 1D diagonal but planned worse: "
        f"{secs_2d:.3e}s > {secs_1d:.3e}s")

    fcfg = T2DConfig(name="fold", n_layers=LAYERS, d_model=d, n_heads=4,
                     d_ff=256, in_dim=16, modulate=False, dtype=jnp.float32)
    fb, ft, fs = 2, 4, 12
    fold_st = stages2d(fcfg, t_len=ft, s_len=fs, batch=fb)
    assert not any(layout_allows(stg, (dim, dim), grid2d)
                   for stg in fold_st for dim in (1, 2, 3)), (
        "fold instance must be unshardable in the 1D (diagonal) space")
    p2 = dsp2d_schedule(fcfg, grid2d, t_len=ft, s_len=fs, batch=fb,
                        topology=topo2d)
    ex2d = ScheduleExecutor2D(p2, backend="null")
    expected2d = ex2d.expected_carry_collectives(pairs)
    r2d = spmd_measure(N, "layout2d", batch=fb, temporal=ft, spatial=fs,
                       layers=LAYERS, d_model=d, heads=4, modulate=False,
                       sp_outer=grid2d[0])
    assert {k: int(v) for k, v in r2d["by_kind_count"].items()
            if v} == expected2d, (r2d["by_kind_count"], expected2d)
    record["layout2d"] = {
        "grid": list(grid2d),
        "bench_planned_seconds": {"plan_1d": secs_1d, "plan_2d": secs_2d},
        "fold_config": {"batch": fb, "temporal": ft, "spatial": fs,
                        "d_model": d, "n_heads": 4},
        "fold_layouts_per_period": [list(lo) for lo in p2.layouts],
        "fold_planned_bytes": p2.schedule.per_device_bytes(),
        "fold_planned_seconds_ici_dcn": p2.schedule.per_device_seconds(),
        "fold_measured_bytes": r2d["collective_bytes_per_dev"],
        "counts": r2d["by_kind_count"],
        "expected_counts": expected2d,
    }
    emit("table3/layout2d/conservative", None,
         f"planned_seconds_1d={secs_1d:.3e};planned_seconds_2d={secs_2d:.3e}"
         f";embedded_diagonal={all(lo[0] == lo[1] for lo in plan_2d)}")
    emit("table3/layout2d/fold", None,
         f"planned_bytes={p2.schedule.per_device_bytes():.0f};"
         f"measured={r2d['collective_bytes_per_dev']:.0f};"
         f"counts={r2d['by_kind_count']};"
         f"layouts={[list(lo) for lo in p2.layouts]}")

    # ---- unified-plan HYBRID row (the (stage, dim, strategy) DP) ----------
    # Instance: long-temporal latents (T=128, S=4) with GQA (8 q heads, 4 kv
    # heads) on the ICI x DCN fabric.  S=4 divides the per-host ICI group
    # but NOT the 8-way SP axis, so dim 2's shard can only live inside a
    # host (placement={2: ("ici",)} is forced) — pure DSP's alternation
    # pays a cross-placement switch + DCN gather per pair, while the DP's
    # hybrid pick stays resident on T and runs USP at temporal stages: a2a
    # q/k/v inside ICI, K/V ring across DCN.  kv_heads=4 also handicaps
    # pure Ulysses (4 % 8 != 0 -> K/V replication).  Runs under --quick so
    # CI smokes the row.
    from repro.models.transformer2d import (strategy_schedule,
                                            stages as t2d_stages)
    from repro.core.topology import Topology
    from repro.core.plan import (StrategyPlan, plan_switches_dp,
                                 strategy_plan_cost)
    hb, ht, hs, hd = 2, 128, 4, 128
    h_outer = 2
    hcfg = T2DConfig(name="hybrid", n_layers=LAYERS, d_model=hd, n_heads=8,
                     d_ff=256, in_dim=16, modulate=False, n_kv_heads=4,
                     dtype=jnp.float32)
    hm_bytes = hb * ht * hs * hd * 4
    hkv_bytes = 2.0 * hb * ht * hs * hcfg.kvh * hcfg.dh * 4
    topo_h = Topology.multihost(2, N // 2, placement={2: ("ici",)})
    hsched = strategy_schedule(hcfg, N, t_len=ht, s_len=hs, batch=hb,
                               topology=topo_h, initial=1)
    hstages = t2d_stages(hcfg, t_len=ht, s_len=hs, batch=hb)
    hybrid_planned = hsched.schedule.strategy_seconds() / pairs

    # every PURE mode on the same instance/fabric, priced by the same
    # strategy cost model: dsp = the classic switch DP's plan; the embedded
    # modes stay resident on T and run their strategy at temporal stages
    pure = {}
    dsp_dims = plan_switches_dp(hstages, [1, 2], topology=topo_h,
                                initial=1, final=1)
    pure["dsp"] = strategy_plan_cost(
        hstages, StrategyPlan(tuple(dsp_dims), ("dsp",) * LAYERS),
        topology=topo_h, initial=1, final=1) / pairs
    for strat in ("ulysses", "ring", "megatron"):
        plan = StrategyPlan((1,) * LAYERS, ("dsp", strat) * pairs)
        pure[strat] = strategy_plan_cost(hstages, plan, topology=topo_h,
                                         initial=1, final=1) / pairs
    assert all(hybrid_planned < v for v in pure.values()), (
        f"hybrid planned {hybrid_planned} not strictly cheaper than every "
        f"pure mode: {pure}")

    rh = spmd_measure(N, "hybrid", batch=hb, temporal=ht, spatial=hs,
                      layers=LAYERS, d_model=hd, modulate=False,
                      n_kv_heads=hcfg.kvh, sp_outer=h_outer)
    h_per_pair = rh["collective_bytes_per_dev"] / pairs
    h_analytic = analytic_bytes("hybrid", hm_bytes, N, kv_bytes=hkv_bytes,
                                kv_heads=hcfg.kvh, outer=h_outer)
    record["hybrid"] = {
        "config": {"devices": N, "layers": LAYERS, "batch": hb,
                   "temporal": ht, "spatial": hs, "d_model": hd,
                   "n_heads": hcfg.n_heads, "n_kv_heads": hcfg.kvh,
                   "sp_outer": h_outer, "fabric": "ici_dcn",
                   "placement": {"2": ["ici"]}},
        "strategies_per_period": list(hsched.strategies),
        "dims_per_period": list(hsched.dims),
        "planned_seconds_per_pair": hybrid_planned,
        "pure_planned_seconds_per_pair": pure,
        "measured_bytes_per_pair": h_per_pair,
        "analytic_bytes_per_pair": h_analytic,
        "ratio": h_per_pair / max(h_analytic, 1),
        "counts": rh["by_kind_count"],
    }
    emit("table3/hybrid/planned_seconds", None,
         f"hybrid={hybrid_planned:.3e};"
         + ";".join(f"{k}={v:.3e}" for k, v in pure.items())
         + f";strategies={list(hsched.strategies)}")
    emit("table3/hybrid/bytes", None,
         f"measured_per_pair={h_per_pair:.0f};analytic={h_analytic:.0f};"
         f"ratio={h_per_pair/max(h_analytic, 1):.2f};"
         f"counts={rh['by_kind_count']}")

    if not args.quick:
        # the paper's headline ordering must hold in the measured HLO
        assert rows["dsp"] < rows["ulysses"] < rows["megatron"]
        assert rows["dsp"] < rows["ring"]
        emit("table3/ordering", None,
             f"dsp<ulysses<megatron and dsp<ring confirmed;"
             f"dsp_vs_ulysses_reduction={1 - rows['dsp']/rows['ulysses']:.2%}")

    with open(args.out, "w") as fh:
        json.dump(record, fh, indent=1)
    emit("table3/json", None, f"wrote {args.out}")


if __name__ == "__main__":
    main()
