"""Paper Table 2 + Table 3: per-layer communication volume of each SP method
on the 2D transformer — analytic model AND measured from compiled HLO on a
simulated 8-device ring.

Table 3 claims (activation size M, N devices):
    DSP 2M/N | Ulysses 4M/N | Megatron-SP 8M | Ring 2M

All analytic numbers are priced with the SAME constant the planner and the
schedule executor use (``repro.core.dsp.comm_volume_bytes``: switch = M/N,
gather = M); for DSP the script additionally reports the PLANNED volume from
the model's own solved schedule (``transformer2d.dsp_schedule``) next to the
measured HLO bytes — planned-vs-measured is the executor's contract — and
the planned training ROUND TRIP: forward and backward legs priced
separately (the backward is planned by the joint DP, not assumed to mirror
the forward; see docs/architecture.md §2.4).
"""
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks.common import spmd_measure, emit
from repro.core.dsp import comm_volume_bytes

N = 8
LAYERS = 4          # 2 layer-pairs


def analytic_bytes(mode: str, m_bytes: float, n: int) -> float:
    """Per-layer analytic volume from the shared Table-2 constant."""
    switch = comm_volume_bytes("switch", m_bytes, n)
    gather = comm_volume_bytes("gather", m_bytes, n)
    return {"dsp": 2 * switch,             # 2 planned switches / layer
            "ulysses": 4 * switch,         # q,k,v seq->head + out head->seq
            "ulysses_fused": 4 * switch,   # same volume, half the ops
            "megatron": 8 * gather,        # 4x AG + 4x RS of the full seq
            "ring": 2 * gather}[mode]      # K+V rotate a full M each


def main():
    b, t, s, d = 2, 16, 32, 128
    m_bytes = b * t * s * d * 4          # f32 activation size
    pairs = LAYERS // 2
    rows = {}
    for mode in ["dsp", "ulysses", "ulysses_fused", "ring", "megatron"]:
        r = spmd_measure(N, mode, batch=b, temporal=t, spatial=s,
                         layers=LAYERS, d_model=d, modulate=False)
        per_layer = r["collective_bytes_per_dev"] / pairs
        rows[mode] = per_layer
        pred = analytic_bytes(mode, m_bytes, N)
        emit(f"table3/comm_volume/{mode}", None,
             f"measured_bytes_per_layer={per_layer:.0f};"
             f"analytic={pred:.0f};ratio={per_layer/max(pred, 1):.2f};"
             f"counts={r['by_kind_count']}")

    # planned-vs-measured for DSP: the model's own solved schedule must
    # price what the compiled HLO actually moves
    from repro.models.transformer2d import T2DConfig, dsp_schedule
    import jax.numpy as jnp
    cfg = T2DConfig(name="bench", n_layers=LAYERS, d_model=d, n_heads=8,
                    d_ff=256, in_dim=16, modulate=False, dtype=jnp.float32)
    psched = dsp_schedule(cfg, N, t_len=t, s_len=s, batch=b)
    planned_total = psched.schedule.per_device_bytes(N)
    measured_total = rows["dsp"] * pairs
    emit("table3/planned_vs_measured/dsp", None,
         f"planned_bytes={planned_total:.0f};measured={measured_total:.0f};"
         f"ratio={measured_total/max(planned_total, 1):.2f};"
         f"planned_switches={psched.schedule.n_switches()}")

    # planned SECONDS next to planned bytes: the same schedule priced on two
    # modeled fabrics (flat ICI ring vs the SP group spanning 2 hosts over
    # DCN) — bytes are identical, time is not, which is exactly why the
    # planner optimises seconds on a Topology
    from repro.core.topology import Topology
    for label, topo in (("ici", Topology.flat_ici(N)),
                        ("ici_dcn", Topology.multihost(2, N // 2))):
        secs = psched.schedule.per_device_seconds(topo)
        emit(f"table3/planned_seconds/{label}", None,
             f"planned_bytes={planned_total:.0f};"
             f"planned_seconds={secs:.3e};"
             f"bottleneck_gbps={topo.bottleneck_bandwidth/1e9:.1f}")

    # the ROUND TRIP: training pays the backward's collectives too.  The
    # joint fwd+bwd planner (core.plan.plan_joint) prices the backward as
    # its own stage graph; on this symmetric model the mirrored plan is
    # optimal (bwd == fwd volumes) and the planner must keep it.
    jsched = dsp_schedule(cfg, N, t_len=t, s_len=s, batch=b,
                          joint=True).schedule
    rb = jsched.roundtrip_bytes(N)
    emit("table3/planned_roundtrip/bytes", None,
         f"fwd_bytes={rb.fwd:.0f};bwd_bytes={rb.bwd:.0f};"
         f"total={rb.total:.0f};bwd_mirrored={jsched.mirrored}")
    assert jsched.mirrored and rb.bwd == rb.fwd
    for label, topo in (("ici", Topology.flat_ici(N)),
                        ("ici_dcn", Topology.multihost(2, N // 2))):
        rs = jsched.roundtrip_seconds(topo)
        emit(f"table3/planned_roundtrip/{label}", None,
             f"fwd_seconds={rs.fwd:.3e};bwd_seconds={rs.bwd:.3e};"
             f"roundtrip_seconds={rs.total:.3e}")

    # the paper's headline ordering must hold in the measured HLO
    assert rows["dsp"] < rows["ulysses"] < rows["megatron"]
    assert rows["dsp"] < rows["ring"]
    emit("table3/ordering", None,
         f"dsp<ulysses<megatron and dsp<ring confirmed;"
         f"dsp_vs_ulysses_reduction={1 - rows['dsp']/rows['ulysses']:.2%}")


if __name__ == "__main__":
    main()
