"""Paper Table 2 + Table 3: per-layer communication volume of each SP method
on the 2D transformer — analytic model AND measured from compiled HLO on a
simulated 8-device ring.

Table 3 claims (activation size M, N devices):
    DSP 2M/N | Ulysses 4M/N | Megatron-SP 8M | Ring 2M
"""
from benchmarks.common import spmd_measure, emit

N = 8
LAYERS = 4          # 2 layer-pairs


def analytic_bytes(mode: str, m_bytes: float, n: int) -> float:
    return {"dsp": 2 * m_bytes / n, "ulysses": 4 * m_bytes / n,
            "ulysses_fused": 4 * m_bytes / n,   # same volume, half the ops
            "megatron": 8 * m_bytes, "ring": 2 * m_bytes}[mode]


def main():
    b, t, s, d = 2, 16, 32, 128
    m_bytes = b * t * s * d * 4          # f32 activation size
    pairs = LAYERS // 2
    rows = {}
    for mode in ["dsp", "ulysses", "ulysses_fused", "ring", "megatron"]:
        r = spmd_measure(N, mode, batch=b, temporal=t, spatial=s,
                         layers=LAYERS, d_model=d, modulate=False)
        per_layer = r["collective_bytes_per_dev"] / pairs
        rows[mode] = per_layer
        pred = analytic_bytes(mode, m_bytes, N)
        emit(f"table3/comm_volume/{mode}", None,
             f"measured_bytes_per_layer={per_layer:.0f};"
             f"analytic={pred:.0f};ratio={per_layer/max(pred, 1):.2f};"
             f"counts={r['by_kind_count']}")
    # the paper's headline ordering must hold in the measured HLO
    assert rows["dsp"] < rows["ulysses"] < rows["megatron"]
    assert rows["dsp"] < rows["ring"]
    emit("table3/ordering", None,
         f"dsp<ulysses<megatron and dsp<ring confirmed;"
         f"dsp_vs_ulysses_reduction={1 - rows['dsp']/rows['ulysses']:.2%}")


if __name__ == "__main__":
    main()
