"""Shared benchmark plumbing: subprocess SPMD measurement + CSV output."""
import json
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def spmd_measure(devices: int, mode: str, *, batch=2, temporal=8,
                 spatial=32, layers=4, d_model=128, heads=8, d_ff=256,
                 modulate=True, grad=False, time_it=False, reps=3,
                 overlap=None, n_kv_heads=None, sp_outer=None):
    cfg = dict(devices=devices, mode=mode, batch=batch, temporal=temporal,
               spatial=spatial, layers=layers, d_model=d_model, heads=heads,
               d_ff=d_ff, modulate=modulate, grad=grad, time=time_it,
               reps=reps, overlap=overlap, n_kv_heads=n_kv_heads,
               sp_outer=sp_outer)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_spmd_worker.py"),
         json.dumps(cfg)],
        env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"worker failed ({mode}, n={devices}):\n"
                           f"{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def emit(name: str, us_per_call, derived: str):
    print(f"{name},{us_per_call if us_per_call is not None else ''},{derived}")
