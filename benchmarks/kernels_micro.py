"""Kernel microbenchmarks: wall time of the jitted ops on this host (CPU;
Pallas kernels in interpret mode — correctness-representative, not
TPU-performance-representative) plus the analytic TPU-side roofline time the
BlockSpec tiling implies.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.analysis.roofline import PEAK_FLOPS, HBM_BW
from repro.kernels import ref
from repro.kernels.ops import flash_attention, ssd_scan


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.monotonic()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / reps * 1e6


def main():
    key = jax.random.PRNGKey(0)
    # flash attention: B=1, H=4, S=512, D=64
    b, h, s, d = 1, 4, 512, 64
    q = jax.random.normal(key, (b, h, s, d))
    k = jax.random.normal(key, (b, h, s, d))
    v = jax.random.normal(key, (b, h, s, d))
    fa = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True))
    fr = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us_fa = _time(fa, q, k, v)
    us_fr = _time(fr, q, k, v)
    flops = 4 * b * h * s * s * d / 2      # causal
    tpu_us = flops / PEAK_FLOPS * 1e6
    emit("kernel/flash_attention_interp", f"{us_fa:.0f}",
         f"ref_us={us_fr:.0f};tpu_roofline_us={tpu_us:.3f};"
         f"bhsd={b}x{h}x{s}x{d}")

    # ssd scan: B=1, L=512, H=4, P=32, G=1, S=64
    b, l, hh, p, g, st = 1, 512, 4, 32, 1, 64
    x = jax.random.normal(key, (b, l, hh, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, l, hh)))
    a = -jnp.exp(jax.random.normal(key, (hh,)) * 0.5)
    bm = jax.random.normal(key, (b, l, g, st))
    cm = jax.random.normal(key, (b, l, g, st))
    ks = jax.jit(lambda *A: ssd_scan(*A, chunk=128))
    rs = jax.jit(lambda *A: ref.ssd_ref(*A))
    us_k = _time(ks, x, dt, a, bm, cm)
    us_r = _time(rs, x, dt, a, bm, cm)
    flops = 2 * b * l * hh * (128 * st + 128 * p + st * p) * 2
    emit("kernel/ssd_scan_interp", f"{us_k:.0f}",
         f"ref_us={us_r:.0f};tpu_roofline_us={flops/PEAK_FLOPS*1e6:.3f};"
         f"blhp={b}x{l}x{hh}x{p}")


if __name__ == "__main__":
    main()
