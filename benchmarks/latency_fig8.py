"""Paper Figure 8: inference latency comparison.

Latency model on the target pod for one diffusion step of the 720M model at
the paper's strong-scaling setting: compute/N + measured comm bytes/ICI.
Reported as relative latency vs DSP (paper: DSP 29-63% faster).
"""
from benchmarks.common import spmd_measure, emit
from repro.analysis.roofline import PEAK_FLOPS
from repro.core.topology import ICI_BW

PARAMS = 670e6
SP = 8


def main():
    b0, t0, s0, d0 = 2, 16, 32, 128
    m0 = b0 * t0 * s0 * d0 * 4
    lat = {}
    for mode in ["dsp", "ulysses", "ring", "megatron"]:
        r = spmd_measure(SP, mode, batch=b0, temporal=t0, spatial=s0,
                         layers=4, d_model=d0, modulate=False)
        vol_per_m = r["collective_bytes_per_dev"] / 2 / m0
        # inference: batch 1, temporal 64, spatial 4096 (intra-node table 6)
        tokens = 64 * 4096
        m = tokens * 1152 * 2 / SP
        compute = 2 * PARAMS * tokens / (SP * PEAK_FLOPS)
        comm = vol_per_m * m * 28 / ICI_BW
        lat[mode] = compute + comm
        emit(f"fig8/latency/{mode}", lat[mode] * 1e6,
             f"compute_us={compute*1e6:.1f};comm_us={comm*1e6:.1f}")
    for mode in ("ulysses", "ring", "megatron"):
        emit(f"fig8/speedup_vs_{mode}", None,
             f"dsp_speedup={lat[mode]/lat['dsp']:.3f}x")


if __name__ == "__main__":
    main()
