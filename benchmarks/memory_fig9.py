"""Paper Figure 9: per-device memory of each SP method (weak-scaling
setting), from XLA memory analysis on the simulated 8-device mesh.

The paper's claim: DSP lowest; Megatron-SP holds full-sequence activations
after its all-gathers; Ring bloats cache.  We report temp (activation
working set) bytes per device for fwd+bwd.
"""
from benchmarks.common import spmd_measure, emit


def main():
    rows = {}
    for mode in ["dsp", "ulysses", "ring", "megatron"]:
        r = spmd_measure(8, mode, batch=2, temporal=32, spatial=32,
                         layers=4, d_model=128, modulate=False, grad=True)
        rows[mode] = r["temp_bytes"]
        emit(f"fig9/memory/{mode}", None,
             f"temp_bytes_per_dev={r['temp_bytes']};arg={r['arg_bytes']}")
    emit("fig9/dsp_vs_megatron", None,
         f"dsp_over_megatron={rows['dsp']/max(rows['megatron'],1):.3f}")
    assert rows["dsp"] <= rows["megatron"], rows


if __name__ == "__main__":
    main()
