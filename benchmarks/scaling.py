"""Paper Figures 6 & 7: weak and strong scaling of each SP method.

Weak scaling: per-device workload constant (temporal grows with N).  DSP's
per-device communication is CONSTANT (M grows ~ N, volume M/N), so it scales
~linearly; Megatron-SP's per-device volume grows ~ M (i.e. ~ N) and Ring's
grows likewise — measured here from compiled HLO on 2/4/8 simulated devices.

Strong scaling: total workload fixed, N grows; per-device compute shrinks
1/N while DSP comm shrinks 1/N^2-ish per device (volume M/N over N devices),
so efficiency holds longest.
"""
from benchmarks.common import spmd_measure, emit
from repro.analysis.roofline import PEAK_FLOPS
from repro.core.topology import ICI_BW


def main():
    # ---- weak scaling (fig 6): temporal per device fixed at 8 -------------
    for mode in ["dsp", "ulysses", "ring", "megatron"]:
        per_dev = {}
        for n in (2, 4, 8):
            r = spmd_measure(n, mode, batch=2, temporal=8 * n, spatial=32,
                             layers=2, d_model=128, modulate=False)
            per_dev[n] = r["collective_bytes_per_dev"]
        growth = per_dev[8] / max(per_dev[2], 1)
        emit(f"fig6/weak_comm_bytes/{mode}", None,
             ";".join(f"n{n}={per_dev[n]:.0f}" for n in per_dev)
             + f";growth_2to8={growth:.2f}")
    # DSP per-device volume must stay ~flat under weak scaling, the
    # embedded baselines must grow
    dsp = [spmd_measure(n, "dsp", batch=2, temporal=8 * n, spatial=32,
                        layers=2, d_model=128,
                        modulate=False)["collective_bytes_per_dev"]
           for n in (2, 8)]
    meg = [spmd_measure(n, "megatron", batch=2, temporal=8 * n, spatial=32,
                        layers=2, d_model=128,
                        modulate=False)["collective_bytes_per_dev"]
           for n in (2, 8)]
    emit("fig6/weak_scaling_ratio", None,
         f"dsp_growth={dsp[1]/dsp[0]:.2f};megatron_growth={meg[1]/meg[0]:.2f}")

    # ---- strong scaling (fig 7): total workload fixed ----------------------
    for mode in ["dsp", "ulysses", "ring", "megatron"]:
        eff = {}
        for n in (2, 4, 8):
            r = spmd_measure(n, mode, batch=2, temporal=32, spatial=32,
                             layers=2, d_model=128, modulate=False)
            # model step time on target hw: compute/N + comm/ICI
            flops = 2 * 16 * (2 * 32 * 32) * 128 * 128 * 12   # rough/layer
            compute = flops / n / PEAK_FLOPS
            comm = r["collective_bytes_per_dev"] / ICI_BW
            eff[n] = compute / (compute + comm)
        emit(f"fig7/strong_efficiency/{mode}", None,
             ";".join(f"n{n}={eff[n]:.3f}" for n in eff))


if __name__ == "__main__":
    main()
